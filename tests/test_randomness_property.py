"""Property-based tests (hypothesis) for the paper's §4.1 randomness guarantee
and the protocol's structural invariants.

The theorem: a DL framework iterating a random sequence of unique indices
through Redox receives data in a (uniformly) random order, each file exactly
once. We check:

* exactly-once under arbitrary plan geometry (sizes, chunk_size, slots,
  node counts, budgets) — hypothesis searches the configuration space;
* slot-consistency of redirection (returned file always maps to the same
  abstract location as the requested one);
* empirical uniformity: over many epochs, the file returned for the *first
  access to a location* is ~uniform over that location's n candidates
  (chi-square), i.e. redirection does not bias which chunk member is served.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a [test] extra (pip install -e .[test]). Without it the
    # property tests skip but the module still collects, so the deterministic
    # statistical tests below always run.
    class _AnyStrategy:
        """Stand-in for the `st` module: every attribute/call returns itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")

from repro.core import ChunkingPlan, Cluster, EpochSampler, LocalNode


@st.composite
def plan_geometry(draw):
    n = draw(st.integers(16, 400))
    c = draw(st.integers(1, 16))
    slots = draw(st.integers(c, 4 * c * max(1, n // (4 * c) or 1)))
    seed = draw(st.integers(0, 2**16))
    size_kind = draw(st.sampled_from(["const", "varied"]))
    if size_kind == "const":
        sizes = np.full(n, 128, dtype=np.int64)
    else:
        rng = np.random.default_rng(seed)
        sizes = rng.integers(16, 2048, n).astype(np.int64)
    return n, c, slots, seed, sizes


@given(plan_geometry())
@settings(max_examples=40, deadline=None)
def test_local_exactly_once_any_geometry(geom):
    n, c, slots, seed, sizes = geom
    plan = ChunkingPlan.create(sizes, c, num_slots=slots, seed=seed)
    node = LocalNode(plan, seed=seed)
    node.begin_epoch()
    seq = EpochSampler(n, 1, seed=seed + 1).global_sequence(0)
    returned = [node.request(int(f)).file_id for f in seq]
    assert sorted(returned) == list(range(n))
    assert node.epoch_complete()


@given(plan_geometry())
@settings(max_examples=40, deadline=None)
def test_local_redirection_slot_consistent(geom):
    n, c, slots, seed, sizes = geom
    plan = ChunkingPlan.create(sizes, c, num_slots=slots, seed=seed)
    node = LocalNode(plan, seed=seed)
    node.begin_epoch()
    for f in EpochSampler(n, 1, seed=seed + 2).global_sequence(0):
        res = node.request(int(f))
        assert plan.location_of_file(res.file_id) == plan.location_of_file(
            res.requested
        )


@given(
    plan_geometry(),
    st.integers(2, 5),
    st.integers(0, 2),
    st.sampled_from([64, 1024, 1 << 40]),
)
@settings(max_examples=25, deadline=None)
def test_distributed_exactly_once_any_geometry(geom, nodes, window_exp, budget):
    n, c, slots, seed, sizes = geom
    plan = ChunkingPlan.create(sizes, c, num_slots=slots, seed=seed)
    cluster = Cluster(
        plan,
        nodes,
        remote_memory_limit_bytes=budget,
        prefetch_window=4**window_exp,
        seed=seed,
    )
    sampler = EpochSampler(n, nodes, seed=seed + 3)
    res = cluster.run_epoch(sampler, 0, batch_per_node=max(1, n // (nodes * 7)))
    assert sorted(np.concatenate(res.returned).tolist()) == list(range(n))


@given(plan_geometry())
@settings(max_examples=30, deadline=None)
def test_never_evict_and_byte_conservation(geom):
    n, c, slots, seed, sizes = geom
    plan = ChunkingPlan.create(sizes, c, num_slots=slots, seed=seed)
    node = LocalNode(plan, seed=seed)
    node.begin_epoch()
    for f in EpochSampler(n, 1, seed=seed + 4).global_sequence(0):
        node.request(int(f))
    s = node.stats
    assert s.disk_bytes == s.filled_bytes + s.wasted_bytes
    assert s.filled_bytes == int(sizes.sum())  # each file filled exactly once
    assert node.memory.used_bytes == 0


def test_first_fill_choice_uniform_chi_square():
    """Lemma (§4.1): on the first miss of a location, the serving chunk is
    uniform over the group. Run many single-shot epochs and chi-square the
    identity of the first file returned for location 0."""
    n, c = 120, 4
    plan = ChunkingPlan.create(
        np.full(n, 64, dtype=np.int64), c, num_slots=c, seed=5
    )  # ONE group: n/c = 30 chunks, all mapped to the same abstract chunk
    group_files_at_slot0 = plan.chunk_files[:, 0]
    counts = {int(f): 0 for f in group_files_at_slot0}
    trials = 3000
    for t in range(trials):
        node = LocalNode(plan, seed=t)
        node.begin_epoch()
        # first access of the epoch targets slot 0 (file = chunk 0 slot 0)
        res = node.request(int(plan.chunk_files[0, 0]))
        counts[res.file_id] += 1
    k = len(counts)
    expected = trials / k
    chi2 = sum((obs - expected) ** 2 / expected for obs in counts.values())
    # dof = 29; p=0.001 critical value ~ 58.3. Generous margin against flakes.
    assert chi2 < 70.0, f"first-fill choice looks non-uniform: chi2={chi2:.1f}"


def test_two_job_service_co_refill_streams_stay_uniform(tmp_path):
    """DESIGN.md §9: the co-refill hook narrows refill tie-breaks using only
    *other* jobs' state — which is an independent uniform permutation — so
    each job's returned stream must remain a uniform exactly-once shuffle.
    Run a real 2-job service with co-refill for many epochs and check (a)
    exactly-once per job per epoch and (b) the positional-flatness necessary
    condition of uniformity (as in
    ``test_returned_stream_positionally_unbiased``) for BOTH jobs."""
    from repro.core import ChunkStore
    from repro.data import SyntheticTokenDataset
    from repro.service import DataService

    n, epochs = 64, 240
    ds = SyntheticTokenDataset(n, vocab_size=97, mean_len=12, seed=11)
    store = ds.build_store(tmp_path / "chunks", 4, num_slots=8, seed=6)
    store = ChunkStore.open(store.root)
    svc = DataService(store, co_refill=True)
    for j in range(2):
        svc.open_session(
            f"j{j}", seed=50 + 31 * j, batch_per_node=16, seq_len=16,
            engine="step",
        )
    pos_sum = {f"j{j}": np.zeros(n) for j in range(2)}
    for e in range(epochs):
        streams = {f"j{j}": [] for j in range(2)}
        for job_id, batch in svc.co_epoch(e):
            streams[job_id].append(batch["returned"])
        for job_id, chunks in streams.items():
            ids = np.concatenate(chunks)
            assert sorted(ids.tolist()) == list(range(n)), (e, job_id)
            pos_sum[job_id][ids] += np.arange(n)
    center = (n - 1) / 2
    sigma = np.sqrt((n * n - 1) / 12 / epochs)
    for job_id, sums in pos_sum.items():
        mean_pos = sums / epochs
        assert np.all(np.abs(mean_pos - center) < 5 * sigma), (
            f"{job_id}: co-refill biased some file's serving position"
        )
    assert svc.aggregate_stats().co_refill_hits > 0  # the hook actually fired
    store.close()


def test_returned_stream_positionally_unbiased():
    """Theorem (§4.1): the *returned* stream is a uniform random permutation.
    Check a necessary condition: E[position of each file] is flat across
    files (no file is systematically served early/late)."""
    n, c = 64, 4
    plan = ChunkingPlan.create(np.full(n, 64, dtype=np.int64), c, num_slots=8, seed=6)
    pos_sum = np.zeros(n)
    epochs = 400
    sampler = EpochSampler(n, 1, seed=77)
    for e in range(epochs):
        node = LocalNode(plan, seed=e)
        node.begin_epoch()
        for pos, f in enumerate(sampler.global_sequence(e)):
            pos_sum[node.request(int(f)).file_id] += pos
    mean_pos = pos_sum / epochs
    # Uniform permutation -> each file's mean position ~ (n-1)/2 with
    # std  sqrt((n^2-1)/12 / epochs) ~ 0.92 for n=64, epochs=400.
    center = (n - 1) / 2
    sigma = np.sqrt((n * n - 1) / 12 / epochs)
    assert np.all(np.abs(mean_pos - center) < 5 * sigma), (
        "some file is served at a biased position"
    )
