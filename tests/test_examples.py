"""Examples must stay runnable (subprocess smoke, tiny arguments)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def run_example(args, timeout=420):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable] + args, capture_output=True, text=True,
        cwd=ROOT, env=env, timeout=timeout,
    )


@pytest.mark.slow
class TestExamples:
    def test_quickstart(self):
        p = run_example(["examples/quickstart.py"])
        assert p.returncode == 0, p.stderr[-1500:]
        assert "exactly-once verified" in p.stdout
        assert "redirection in action" in p.stdout

    def test_train_lm_small(self):
        p = run_example(
            ["examples/train_lm.py", "--steps", "12", "--preset", "small",
             "--ckpt-every", "6"]
        )
        assert p.returncode == 0, p.stderr[-1500:]
        assert "done: 12 steps" in p.stdout

    def test_serve_decode(self):
        p = run_example(
            ["examples/serve_decode.py", "--arch", "tinyllama-1.1b",
             "--new-tokens", "6", "--prompt-len", "16"]
        )
        assert p.returncode == 0, p.stderr[-1500:]
        assert "decoded 6 tokens/seq" in p.stdout

    def test_launcher_train_cli(self):
        p = run_example(
            ["-m", "repro.launch.train", "--arch", "xlstm-350m", "--steps", "6",
             "--seq-len", "64", "--num-docs", "256"]
        )
        assert p.returncode == 0, p.stderr[-1500:]
        assert "done: 6 steps" in p.stdout

    def test_launcher_serve_cli(self):
        p = run_example(
            ["-m", "repro.launch.serve", "--arch", "deepseek-moe-16b",
             "--new-tokens", "4", "--prompt-len", "8", "--seed", "3"]
        )
        assert p.returncode == 0, p.stderr[-1500:]
        assert "decoded 4 tok/seq" in p.stdout

    def test_launcher_serve_rejects_encoder(self):
        p = run_example(["-m", "repro.launch.serve", "--arch", "hubert-xlarge"])
        assert p.returncode == 1
        assert "encoder-only" in p.stdout

    def test_launcher_serve_list_archs(self):
        """Explicitly listing archs is the exit-0 path for encoder-only info
        (serving an encoder-only arch stays exit 1, tested above)."""
        p = run_example(["-m", "repro.launch.serve", "--list-archs"])
        assert p.returncode == 0, p.stderr[-1500:]
        assert "hubert-xlarge: encoder-only" in p.stdout
        assert "tinyllama-1.1b: decode" in p.stdout
        assert p.stdout.count("\n") >= 10  # every registered arch listed

    def test_launcher_serve_requires_arch_without_listing(self):
        p = run_example(["-m", "repro.launch.serve"])
        assert p.returncode == 2  # argparse usage error, not a crash
        assert "--arch is required" in p.stderr

    def test_launcher_data_service_cli(self):
        p = run_example(
            ["-m", "repro.launch.data_service", "--jobs", "3", "--num-docs",
             "256", "--batch", "16", "--seq-len", "48", "--co-refill"]
        )
        assert p.returncode == 0, p.stderr[-1500:]
        assert "aggregate:" in p.stdout
        assert "dup_loads_avoided" in p.stdout
