"""Storage-backend matrix: vfs / mmap / parallel serve identical bytes,
and the parallel pipeline preserves the exactly-once epoch invariants."""

import numpy as np
import pytest

from repro.core import (
    ChunkStore,
    Cluster,
    EpochSampler,
    LocalNode,
    ParallelBackend,
    RedoxLoader,
    VFSBackend,
    make_backend,
)
from repro.data import SyntheticTokenDataset

pytestmark = pytest.mark.backend

BACKENDS = ["vfs", "mmap", "parallel"]


@pytest.fixture(scope="module")
def store_dir(tmp_path_factory):
    root = tmp_path_factory.mktemp("chunks")
    ds = SyntheticTokenDataset(192, vocab_size=97, mean_len=48, seed=3)
    store = ds.build_store(root, chunk_size=4, num_slots=16, seed=1)
    return root, store.plan


@pytest.mark.parametrize("backend", BACKENDS)
class TestByteEquivalence:
    def test_chunks_identical(self, store_dir, backend):
        root, plan = store_dir
        ref = ChunkStore.open(root)  # vfs reference
        other = ChunkStore.open(root, backend=backend)
        for k in range(plan.num_chunks):
            a = ref.read_chunk(k)
            b = other.read_chunk(k)
            assert [f for f, _ in a] == [f for f, _ in b]
            for (_, x), (_, y) in zip(a, b):
                assert bytes(x) == bytes(y)
        ref.close()
        other.close()

    def test_records_identical(self, store_dir, backend):
        root, plan = store_dir
        ref = ChunkStore.open(root)
        other = ChunkStore.open(root, backend=backend)
        for fid in range(0, plan.num_files, 7):
            assert bytes(ref.read_file(fid)) == bytes(other.read_file(fid))
        ref.close()
        other.close()

    def test_chunk_and_ranged_reads_agree(self, store_dir, backend):
        root, plan = store_dir
        store = ChunkStore.open(root, backend=backend)
        for k in (0, plan.num_chunks // 2, plan.num_chunks - 1):
            for fid, blob in store.read_chunk(k):
                assert bytes(store.read_file(fid)) == bytes(blob)
        store.close()

    def test_full_epoch_exactly_once(self, store_dir, backend):
        root, plan = store_dir
        store = ChunkStore.open(root, backend=backend)
        node = LocalNode(plan, seed=9, store=store)
        node.begin_epoch()
        seq = EpochSampler(plan.num_files, 1, seed=11).global_sequence(0)
        returned = [node.request(int(f)).file_id for f in seq]
        assert sorted(returned) == list(range(plan.num_files))
        assert node.epoch_complete()
        store.close()


class TestBackendSpecifics:
    def test_factory_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown storage backend"):
            make_backend("tape")

    def test_factory_passes_instances_through(self):
        be = VFSBackend(max_handles=3)
        assert make_backend(be) is be

    def test_mmap_reads_are_zero_copy_views(self, store_dir):
        root, plan = store_dir
        store = ChunkStore.open(root, backend="mmap")
        for _, blob in store.read_chunk(0):
            assert isinstance(blob, memoryview)
        assert isinstance(store.read_file(0), memoryview)
        store.close()

    def test_parallel_prefetch_hits_and_bounded_inflight(self, store_dir):
        root, plan = store_dir
        be = ParallelBackend(workers=2, readahead=6)
        store = ChunkStore.open(root, backend=be)
        node = LocalNode(plan, seed=4, store=store)
        node.begin_epoch()
        for f in EpochSampler(plan.num_files, 1, seed=5).global_sequence(0):
            node.request(int(f))
        assert node.epoch_complete()
        assert be.stats.prefetch_issued > 0
        assert be.stats.prefetch_hits > 0
        assert be.stats.peak_inflight <= 6
        assert node.stats.peak_inflight_reads <= 6
        assert node.stats.read_wait_s > 0
        store.close()

    def test_tiny_handle_cache_under_concurrency(self, store_dir):
        """fd eviction must never close a descriptor a concurrent reader
        holds: with max_handles=1 every read evicts the previous handle
        while pool workers are mid-pread."""
        root, plan = store_dir
        be = ParallelBackend(VFSBackend(max_handles=1), workers=4, readahead=6)
        store = ChunkStore.open(root, backend=be)
        ref = ChunkStore.open(root)
        for k in range(plan.num_chunks):
            store.prefetch_chunks(list(range(k, min(k + 6, plan.num_chunks))))
            a = store.read_chunk(k)
            b = ref.read_chunk(k)
            for (fa, xa), (fb, xb) in zip(a, b):
                assert fa == fb and bytes(xa) == bytes(xb)
        store.close()
        ref.close()

    def test_parallel_close_is_idempotent(self, store_dir):
        root, _ = store_dir
        store = ChunkStore.open(root, backend="parallel")
        store.read_chunk(0)
        store.close()
        store.close()


class TestParallelPipeline:
    """Exactly-once + identical batches through the async loader pipeline."""

    def _epoch_grids(self, root, backend, queue_depth, asynchronous):
        store = ChunkStore.open(root, backend=backend)
        cluster = Cluster(store.plan, 2, store=store, seed=6)
        sampler = EpochSampler(store.plan.num_files, 2, seed=7)
        loader = RedoxLoader(
            cluster, sampler, batch_per_node=8, seq_len=32, queue_depth=queue_depth
        )
        it = loader.epoch_async(0) if asynchronous else loader.epoch(0)
        grids = [b["tokens"].copy() for b in it]
        # _produce ran _check_epoch_complete; re-assert the drained state here.
        for node in cluster.nodes:
            assert node.memory.is_empty()
        store.close()
        return grids

    @pytest.mark.parametrize("queue_depth", [2, 4])
    def test_exactly_once_under_queue_depth(self, store_dir, queue_depth):
        root, _ = store_dir
        ref = self._epoch_grids(root, "vfs", queue_depth=2, asynchronous=False)
        par = self._epoch_grids(root, "parallel", queue_depth, asynchronous=True)
        assert len(ref) == len(par)
        for a, b in zip(ref, par):
            np.testing.assert_array_equal(a, b)

    def test_parallel_overlap_beats_vfs_with_latency(self, store_dir):
        """With real per-op storage latency, readahead must cut the blocked
        read-wait: every prefetched chunk is eventually re-loaded, so hits
        convert whole sleeps into (near-)free claims."""
        root, _ = store_dir
        latency = 3e-3

        def epoch_wait(backend):
            store = ChunkStore.open(root, backend=backend)
            node = LocalNode(store.plan, seed=8, store=store)
            node.begin_epoch()
            for f in EpochSampler(store.plan.num_files, 1, seed=9).global_sequence(0):
                node.request(int(f))
            wait = node.stats.read_wait_s
            store.close()
            return wait

        vfs_wait = epoch_wait(VFSBackend(latency_s=latency))
        par_wait = epoch_wait(
            ParallelBackend(VFSBackend(latency_s=latency), workers=4, readahead=16)
        )
        assert par_wait < 0.9 * vfs_wait, (
            f"parallel backend did not overlap reads: {par_wait:.3f}s vs "
            f"vfs {vfs_wait:.3f}s"
        )
