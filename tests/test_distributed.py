"""Distributed protocol tests: ownership, prefetch, ablations, elasticity (paper §3.4)."""

import numpy as np
import pytest

from repro.core import ChunkingPlan, Cluster, EpochSampler


def make(n=960, c=8, slots=64, nodes=3, seed=0, sizes=None, **kw):
    sizes = np.full(n, 100, dtype=np.int64) if sizes is None else sizes
    plan = ChunkingPlan.create(sizes, c, num_slots=slots, seed=seed)
    cluster = Cluster(plan, nodes, seed=seed, **kw)
    sampler = EpochSampler(n, nodes, seed=seed + 99)
    return plan, cluster, sampler


class TestDistributedProtocol:
    @pytest.mark.parametrize("prefetch", [True, False])
    @pytest.mark.parametrize("policy", ["max_fill", "random"])
    def test_global_exactly_once(self, prefetch, policy):
        _, cluster, sampler = make(prefetch=prefetch, policy=policy)
        res = cluster.run_epoch(sampler, 0, batch_per_node=16)
        all_returned = np.concatenate(res.returned)
        assert sorted(all_returned.tolist()) == list(range(960))

    def test_multi_epoch(self):
        _, cluster, sampler = make()
        for epoch in range(3):
            res = cluster.run_epoch(sampler, epoch, batch_per_node=16)
            assert sorted(np.concatenate(res.returned).tolist()) == list(range(960))

    def test_prefetch_reduces_remote_requests(self):
        """Paper Table 5: prefetching collapses remote on-demand requests."""
        _, c_pf, sampler = make(prefetch=True)
        _, c_np, _ = make(prefetch=False)
        r_pf = c_pf.run_epoch(sampler, 0, batch_per_node=16)
        r_np = c_np.run_epoch(sampler, 0, batch_per_node=16)
        assert r_pf.stats.remote_requests < r_np.stats.remote_requests
        assert r_pf.stats.remote_prefetch_hits > 0
        assert r_np.stats.remote_prefetch_hits == 0

    def test_prefetch_improves_fill_rate(self):
        """Paper Fig. 7: shipping prefetches frees slots -> higher fill rate."""
        _, c_pf, sampler = make(prefetch=True, nodes=4)
        _, c_np, _ = make(prefetch=False, nodes=4)
        r_pf = c_pf.run_epoch(sampler, 0, batch_per_node=16)
        r_np = c_np.run_epoch(sampler, 0, batch_per_node=16)
        assert r_pf.stats.mean_fill_rate >= r_np.stats.mean_fill_rate

    def test_remote_memory_budget_respected(self):
        sizes = np.full(960, 100, dtype=np.int64)
        limit = 500  # only 5 files' worth of remote memory
        _, cluster, sampler = make(
            sizes=sizes, remote_memory_limit_bytes=limit, prefetch=True
        )
        cluster.run_epoch(sampler, 0, batch_per_node=16)
        for st in (n.stats for n in cluster.nodes):
            assert st.peak_remote_bytes <= limit

    def test_larger_remote_memory_more_prefetch(self):
        """Paper Fig. 12 trend: bigger budget -> more prefetched data (to a point)."""
        received = []
        for limit in (200, 2000, 10**9):
            _, cluster, sampler = make(remote_memory_limit_bytes=limit)
            res = cluster.run_epoch(sampler, 0, batch_per_node=16)
            received.append(res.stats.prefetch_received)
        assert received[0] <= received[1] <= received[2]
        assert received[2] > 0

    def test_single_node_cluster_matches_local(self):
        _, cluster, sampler = make(nodes=1)
        res = cluster.run_epoch(sampler, 0, batch_per_node=16)
        assert res.stats.remote_requests == 0
        assert res.stats.prefetch_sent == 0
        assert sorted(res.returned[0].tolist()) == list(range(960))

    def test_owner_disk_io_attribution(self):
        _, cluster, sampler = make(nodes=3, prefetch=False)
        res = cluster.run_epoch(sampler, 0, batch_per_node=16)
        # all disk traffic is chunk-granular: no per-file reads ever
        for steps in res.per_node_step_io:
            for io in steps:
                assert io.file_reads == 0

    def test_ablation_grid_runs(self):
        """The four paper variants (Table 4) all satisfy exactly-once."""
        for policy in ("max_fill", "random"):
            for prefetch in (True, False):
                _, cluster, sampler = make(policy=policy, prefetch=prefetch)
                res = cluster.run_epoch(sampler, 0, batch_per_node=16)
                assert sorted(np.concatenate(res.returned).tolist()) == list(
                    range(960)
                )


class TestElasticity:
    def test_mid_epoch_failure_preserves_exactly_once(self):
        n, nodes = 960, 3
        _, cluster, sampler = make(n=n, nodes=nodes)
        seqs = cluster.begin_epoch(sampler, 0)
        returned = []
        io = {}
        # every node processes its first 100 accesses
        upto = 100
        for r in range(nodes):
            for pos in range(upto):
                f, _ = cluster.access(r, pos, int(seqs[r][pos]), io)
                returned.append(f)
        # node 2 dies; its tail is redistributed, ownership remapped
        cluster.fail_node(2, processed_upto=upto)
        for r in (0, 1):
            seq = cluster.sequences[r]
            for pos in range(upto, len(seq)):
                f, _ = cluster.access(r, pos, int(seq[pos]), io)
                returned.append(f)
        assert sorted(returned) == list(range(n)), (
            "files lost or duplicated across the failure"
        )

    def test_failure_with_outstanding_prefetches(self):
        # Stress: many prefetches in flight when the node dies.
        n, nodes = 1920, 4
        _, cluster, sampler = make(n=n, nodes=nodes, slots=128, prefetch=True)
        seqs = cluster.begin_epoch(sampler, 0)
        returned = []
        io = {}
        upto = 150
        for r in range(nodes):
            for pos in range(upto):
                f, _ = cluster.access(r, pos, int(seqs[r][pos]), io)
                returned.append(f)
        cluster.fail_node(1, processed_upto=upto)
        for r in (0, 2, 3):
            seq = cluster.sequences[r]
            for pos in range(upto, len(seq)):
                f, _ = cluster.access(r, pos, int(seq[pos]), io)
                returned.append(f)
        assert sorted(returned) == list(range(n))

    def test_ownership_fully_reassigned(self):
        _, cluster, sampler = make(nodes=3)
        cluster.begin_epoch(sampler, 0)
        cluster.fail_node(0, processed_upto=0)
        assert not (cluster.owner_of_group == 0).any()
