"""int8 KV-cache quantization: accuracy + cache-structure tests (§Perf lever)."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model, split_params
from repro.models.attention import dequantize_kv, quantize_kv
from repro.train.train_step import build_decode_step, build_prefill_step


class TestQuantPrimitive:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        t = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
        q, s = quantize_kv(t)
        back = dequantize_kv(q, s, jnp.float32)
        rel = float(jnp.max(jnp.abs(back - t)) / jnp.max(jnp.abs(t)))
        assert q.dtype == jnp.int8 and s.dtype == jnp.bfloat16
        assert rel < 0.02  # 1/127 per-row symmetric quantisation

    def test_zero_rows_safe(self):
        q, s = quantize_kv(jnp.zeros((3, 8)))
        assert np.all(np.asarray(q) == 0)
        assert np.isfinite(np.asarray(s, np.float32)).all()


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "deepseek-moe-16b"])
class TestQuantizedDecode:
    def test_prefill_decode_close_to_fp(self, name):
        base = reduced(ARCHS[name])
        if base.moe_num_experts:
            base = dataclasses.replace(base, capacity_factor=64.0)
        qcfg = dataclasses.replace(base, kv_cache_dtype="int8")
        rng = np.random.default_rng(4)
        T, b = 16, 2
        toks = jnp.asarray(rng.integers(0, base.vocab_size, (b, T + 1)), jnp.int32)

        outs = {}
        for cfg in (base, qcfg):
            model = build_model(cfg)
            values, _ = split_params(model.init(0))
            prefill = build_prefill_step(model, max_len=32)
            decode = build_decode_step(model)
            _, cache = prefill(values, {"tokens": toks[:, :T]})
            lg, _ = decode(values, cache, toks[:, T : T + 1], jnp.int32(T))
            outs[cfg.kv_cache_dtype] = np.asarray(lg[:, 0], np.float32)
        err = np.max(np.abs(outs[""] - outs["int8"]))
        scale = np.max(np.abs(outs[""])) + 1e-9
        assert err / scale < 0.05, err / scale
        # ranking of the argmax token should survive quantisation
        assert (outs[""].argmax(-1) == outs["int8"].argmax(-1)).mean() >= 0.5

    def test_cache_is_int8(self, name):
        qcfg = dataclasses.replace(reduced(ARCHS[name]), kv_cache_dtype="int8")
        model = build_model(qcfg)
        cache = model.init_cache(batch=2, max_len=16)
        leaves = jax.tree.leaves(cache)
        assert any(l.dtype == jnp.int8 for l in leaves)
        # int8 cache + bf16 scales is ~half the bf16 cache footprint
        q_bytes = sum(l.size * l.dtype.itemsize for l in leaves)
        fp = build_model(reduced(ARCHS[name])).init_cache(batch=2, max_len=16)
        fp_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(fp))
        assert q_bytes < 0.7 * fp_bytes