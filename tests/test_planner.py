"""Plan-vs-execute equivalence: the clairvoyant planner's core contract.

Three ways to run an epoch must be byte-identical:

* the reference per-access walk (``engine="per_access"`` — scalar check-list
  helpers, one ``Cluster.access`` per position);
* the batched id-space walk (``engine="step"`` — vectorised hit runs and
  check-list cleanup);
* replay of an :class:`EpochPlan` computed by :class:`EpochPlanner` on a
  store-less clone.

"Byte-identical" covers the returned (redirected) stream, the chunk-load
event sequence with fill rates and filled files, the opportunistic ships,
the per-step StepIO counters, and the end-of-epoch NodeStats.
"""

import numpy as np
import pytest

# The elastic differential harness owns the equivalence helpers; this file
# reuses them (make/assert_same_epoch) and keeps the plan-vs-execute grid.
from elastic_harness import assert_same_epoch, make
from repro.core import Cluster, EpochPlanner, EpochSampler
from repro.core.planner import PlanRecorder

pytestmark = pytest.mark.planner

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests become a no-op; the grid below remains
    HAVE_HYPOTHESIS = False


def run_three_ways(make_kwargs, batch, epoch=0, failures=None):
    """(per_access result+recorder, step result+recorder, replay result, plan)."""
    c1, sampler = make(**make_kwargs)
    c2, _ = make(**make_kwargs)
    c3, _ = make(**make_kwargs)
    rec1, rec2 = PlanRecorder(), PlanRecorder()
    r1 = c1.run_epoch(
        sampler, epoch, batch, engine="per_access", recorder=rec1, failures=failures
    )
    r2 = c2.run_epoch(
        sampler, epoch, batch, engine="step", recorder=rec2, failures=failures
    )
    plan = EpochPlanner(c3).plan(sampler, epoch, batch, failures=failures)
    r3 = c3.run_epoch(sampler, epoch, batch, plan=plan)
    return (r1, rec1), (r2, rec2), r3, plan


class TestPlanEquivalence:
    @pytest.mark.parametrize("nodes", [1, 2, 3, 5])
    @pytest.mark.parametrize("policy", ["max_fill", "random"])
    def test_engines_and_replay_identical(self, nodes, policy):
        kw = dict(nodes=nodes, policy=policy)
        (r1, rec1), (r2, rec2), r3, plan = run_three_ways(kw, batch=16)
        assert_same_epoch(r1, r2, rec1, rec2)
        assert_same_epoch(r1, r3)
        # the plan's own arrays equal the recorded live event stream
        np.testing.assert_array_equal(plan.load_chunk, np.asarray(rec1.load_chunk))
        np.testing.assert_array_equal(plan.ship_file, np.asarray(rec1.ship_file))

    @pytest.mark.parametrize("prefetch", [True, False])
    def test_prefetch_ablations_identical(self, prefetch):
        kw = dict(nodes=3, prefetch=prefetch)
        (r1, rec1), (r2, rec2), r3, _ = run_three_ways(kw, batch=16)
        assert_same_epoch(r1, r2, rec1, rec2)
        assert_same_epoch(r1, r3)

    def test_variable_sizes_and_tight_remote_memory(self):
        rng = np.random.default_rng(5)
        sizes = rng.integers(40, 400, 960).astype(np.int64)
        kw = dict(nodes=3, sizes=sizes, remote_memory_limit_bytes=2_000)
        (r1, rec1), (r2, rec2), r3, _ = run_three_ways(kw, batch=16)
        assert_same_epoch(r1, r2, rec1, rec2)
        assert_same_epoch(r1, r3)

    def test_fail_node_mid_epoch_identical(self):
        """Elastic remap (paper §5 / DESIGN.md §5) planned == executed."""
        failures = {3: 2}  # node 2 dies at the step-3 barrier
        kw = dict(nodes=3)
        (r1, rec1), (r2, rec2), r3, plan = run_three_ways(
            kw, batch=16, failures=failures
        )
        assert_same_epoch(r1, r2, rec1, rec2)
        assert_same_epoch(r1, r3)
        # the epoch stayed exactly-once through the failure
        all_returned = np.concatenate(r1.returned)
        assert sorted(all_returned.tolist()) == list(range(960))

    def test_multi_epoch_plans_are_epoch_independent(self):
        """Per-epoch RNG derivation: planning epoch e needs no history."""
        kw = dict(nodes=3)
        c_live, sampler = make(**kw)
        results = [c_live.run_epoch(sampler, e, 16, engine="step") for e in range(2)]
        # plan epoch 1 on a fresh clone that never saw epoch 0
        c_replay, _ = make(**kw)
        plan1 = EpochPlanner(c_replay).plan(sampler, 1, 16)
        r1 = c_replay.run_epoch(sampler, 1, 16, plan=plan1)
        assert_same_epoch(results[1], r1)

    def test_plan_counters(self):
        kw = dict(nodes=3)
        c3, sampler = make(**kw)
        plan = EpochPlanner(c3).plan(sampler, 0, 16)
        assert plan.stats.planned_accesses == 960
        assert plan.stats.planned_chunk_loads == plan.load_chunk.size > 0
        assert plan.stats.plan_time_s > 0
        agg = plan.node_stats[0]
        for s in plan.node_stats[1:]:
            agg = agg.merge(s)
        assert agg.chunk_loads == plan.load_chunk.size
        assert agg.prefetch_sent == plan.ship_file.size


class TestScheduledReads:
    def test_loader_uses_exact_schedule(self, tmp_path):
        """Real-bytes: the planner hands the exact chunk schedule to the
        parallel backend; every backend read is then a scheduled hit."""
        from repro.core import ChunkStore, ParallelBackend, RedoxLoader
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(192, vocab_size=97, mean_len=48, seed=3)
        store = ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        store = ChunkStore.open(store.root, backend=ParallelBackend(workers=2))
        cluster = Cluster(store.plan, 1, store=store, seed=2)
        sampler = EpochSampler(192, 1, seed=4)
        loader = RedoxLoader(cluster, sampler, batch_per_node=16, seq_len=32)
        n = sum(1 for _ in loader.epoch(0))
        assert n == loader.steps_per_epoch()
        b = store.backend_stats
        assert b.scheduled_hits > 0
        assert b.scheduled_hits == b.chunk_reads  # clairvoyant: no cold reads
        assert loader.last_plan is not None
        assert loader.last_plan.stats.scheduled_read_hits == b.scheduled_hits
        store.close()

    def test_planner_off_uses_heuristic(self, tmp_path):
        from repro.core import ChunkStore, ParallelBackend, RedoxLoader
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(192, vocab_size=97, mean_len=48, seed=3)
        store = ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        store = ChunkStore.open(store.root, backend=ParallelBackend(workers=2))
        cluster = Cluster(store.plan, 1, store=store, seed=2)
        sampler = EpochSampler(192, 1, seed=4)
        loader = RedoxLoader(
            cluster, sampler, batch_per_node=16, seq_len=32, use_planner=False
        )
        sum(1 for _ in loader.epoch(0))
        b = store.backend_stats
        assert b.scheduled_hits == 0
        assert b.prefetch_hits > 0  # _refill_hints readahead fallback
        store.close()

    def test_replay_grid_mismatch_rejected(self):
        c, sampler = make(nodes=3)
        plan = EpochPlanner(c).plan(sampler, 0, 16)
        with pytest.raises(ValueError, match="batch_per_node"):
            c.run_epoch(sampler, 0, 32, plan=plan)
        with pytest.raises(ValueError, match="epoch"):
            c.run_epoch(sampler, 1, 16, plan=plan)
        with pytest.raises(ValueError, match="stepping"):
            # loader-style floor_tail replay of a ceil plan
            next(c.replay_stream(plan, stepping="floor_tail"))

    def test_abandoned_epoch_does_not_poison_schedule(self, tmp_path):
        """Regression: schedule_reads replaces a stale schedule, so a
        consumer that bails mid-epoch cannot block the next epoch's
        clairvoyant readahead."""
        from repro.core import ChunkStore, ParallelBackend, RedoxLoader
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(192, vocab_size=97, mean_len=48, seed=3)
        store = ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        store = ChunkStore.open(store.root, backend=ParallelBackend(workers=2))
        cluster = Cluster(store.plan, 1, store=store, seed=2)
        sampler = EpochSampler(192, 1, seed=4)
        loader = RedoxLoader(cluster, sampler, batch_per_node=16, seq_len=32)
        gen = loader.epoch(0)
        next(gen)
        gen.close()  # abandon epoch 0 mid-replay, schedule partially drained
        # epoch 0's protocol state is mid-flight; rebuild a fresh cluster on
        # the same (still-open) store and run a clean epoch through it
        cluster2 = Cluster(store.plan, 1, store=store, seed=2)
        loader2 = RedoxLoader(cluster2, sampler, batch_per_node=16, seq_len=32)
        before_reads = store.backend_stats.chunk_reads
        before_hits = store.backend_stats.scheduled_hits
        n = sum(1 for _ in loader2.epoch(0))
        assert n == loader2.steps_per_epoch()
        reads = store.backend_stats.chunk_reads - before_reads
        hits = store.backend_stats.scheduled_hits - before_hits
        # every read of the clean epoch was served by its own (fresh)
        # schedule — stale epoch-0 entries must not have blocked readahead
        assert reads > 0 and hits == reads
        store.close()

    def test_planned_and_live_batches_identical(self, tmp_path):
        from repro.core import RedoxLoader
        from repro.data import SyntheticTokenDataset

        batches = []
        for use_planner in (True, False):
            ds = SyntheticTokenDataset(192, vocab_size=97, mean_len=48, seed=3)
            root = tmp_path / f"chunks_{use_planner}"
            store = ds.build_store(root, 4, num_slots=16, seed=1)
            cluster = Cluster(store.plan, 2, store=store, seed=2)
            sampler = EpochSampler(192, 2, seed=4)
            loader = RedoxLoader(
                cluster, sampler, batch_per_node=8, seq_len=32,
                use_planner=use_planner,
            )
            batches.append([b["tokens"].copy() for b in loader.epoch(0)])
        assert len(batches[0]) == len(batches[1])
        for a, b in zip(*batches):
            np.testing.assert_array_equal(a, b)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        nodes=st.integers(1, 4),
        chunk_size=st.integers(2, 10),
        groups=st.integers(1, 6),
        n_chunks=st.integers(4, 40),
        policy=st.sampled_from(["max_fill", "random"]),
        prefetch=st.booleans(),
        batch=st.integers(4, 32),
        seed=st.integers(0, 1000),
    )
    def test_equivalence_property(
        nodes, chunk_size, groups, n_chunks, policy, prefetch, batch, seed
    ):
        n = chunk_size * n_chunks
        kw = dict(
            n=n, c=chunk_size, slots=groups * chunk_size,
            nodes=nodes, seed=seed, policy=policy, prefetch=prefetch,
        )
        (r1, rec1), (r2, rec2), r3, _ = run_three_ways(kw, batch=batch)
        assert_same_epoch(r1, r2, rec1, rec2)
        assert_same_epoch(r1, r3)
