#!/usr/bin/env python
"""Regenerate tests/golden/streams.json (the golden-stream fixtures).

Run after an *intentional* change to the protocol's shuffle/redirection
behaviour, then review the diff — an unintentional stream change should
fail tests/test_golden_streams.py instead of being regenerated away:

    python tests/golden/regen.py
"""

import json
import sys
from pathlib import Path

HERE = Path(__file__).resolve()
sys.path.insert(0, str(HERE.parents[2] / "src"))
sys.path.insert(0, str(HERE.parents[1]))  # tests/ for elastic_harness

from elastic_harness import golden_streams  # noqa: E402


def main() -> int:
    out = HERE.parent / "streams.json"
    out.write_text(json.dumps(golden_streams(), indent=1) + "\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
