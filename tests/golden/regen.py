#!/usr/bin/env python
"""Regenerate the golden fixtures: streams.json (protocol streams) and
frames.json (compressed chunk frames, tests/test_codec.py).

Run after an *intentional* change to the protocol's shuffle/redirection
behaviour or the frame container format, then review the diff — an
unintentional change should fail the golden tests instead of being
regenerated away:

    python tests/golden/regen.py
"""

import base64
import json
import sys
from pathlib import Path

HERE = Path(__file__).resolve()
sys.path.insert(0, str(HERE.parents[2] / "src"))
sys.path.insert(0, str(HERE.parents[1]))  # tests/ for elastic_harness

from elastic_harness import golden_streams  # noqa: E402


def golden_frames() -> list:
    """One framed chunk per registry codec: the raw band payloads (what
    decode must return) plus the encoded frame bytes (what parse_frame
    must accept — decode stability across codec versions, not encode
    byte-equality, is the pinned contract)."""
    from repro.core.storage.codec import CODECS, band_cuts, encode_frame

    # Deterministic compressible "records": repetitive token-ish bytes.
    body = bytes(
        (7 * i + (i >> 3)) % 251 for i in range(1536)
    ) + b"\x00\x01\x02\x03" * 128
    cuts = band_cuts(len(body), 3)
    bands = [body[cuts[b]:cuts[b + 1]] for b in range(3)]
    out = []
    for name in sorted(CODECS):
        codec = CODECS[name]
        frame = encode_frame(name, [codec.encode(b) for b in bands])
        out.append({
            "codec": name,
            "bands": [base64.b64encode(b).decode() for b in bands],
            "frame": base64.b64encode(bytes(frame)).decode(),
        })
    return out


def main() -> int:
    out = HERE.parent / "streams.json"
    out.write_text(json.dumps(golden_streams(), indent=1) + "\n")
    print(f"wrote {out}")
    frames = HERE.parent / "frames.json"
    frames.write_text(json.dumps(golden_frames(), indent=1) + "\n")
    print(f"wrote {frames}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
