"""Integration: training loop × Redox loader × optimizers × microbatching."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.core import Cluster, EpochSampler, RedoxLoader
from repro.data import SyntheticTokenDataset
from repro.launch.specs import dummy_train_inputs
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.train.train_step import build_train_step, init_train_state


def _setup(name="tinyllama-1.1b", **run_kw):
    cfg = reduced(ARCHS[name])
    model = build_model(cfg)
    run = RunConfig(optimizer=run_kw.pop("optimizer", "adamw"),
                    learning_rate=1e-3, **run_kw)
    opt = make_optimizer(run)
    state = init_train_state(model, opt, 0)
    return cfg, model, run, opt, state


class TestOptimizers:
    @pytest.mark.parametrize("optimizer", ["adamw", "adafactor", "sgdm"])
    def test_descends(self, optimizer):
        cfg, model, run, opt, state = _setup(optimizer=optimizer)
        step = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
        batch = dummy_train_inputs(cfg, 4, 64, seed=0)
        losses = []
        for _ in range(5):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], (optimizer, losses)

    def test_no_master_tracks_master(self):
        """bf16-params + no fp32 master must follow the master trajectory
        closely for a few steps (the kimi-k2 memory recipe)."""
        losses = {}
        for master in (True, False):
            cfg, model, run, opt, state = _setup(
                optimizer="adafactor", master_fp32=master
            )
            step = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
            batch = dummy_train_inputs(cfg, 4, 64, seed=0)
            ls = []
            for _ in range(5):
                state, m = step(state, batch)
                ls.append(float(m["loss"]))
            losses[master] = ls
        np.testing.assert_allclose(losses[True], losses[False], rtol=5e-3)

    def test_adafactor_state_is_factored(self):
        cfg, model, run, opt, state = _setup(optimizer="adafactor")
        v = state["opt"]["v"]
        leaves = jax.tree.leaves(v)
        # factored states are strictly smaller than the largest param
        values = jax.tree.leaves(state["values"])
        assert max(l.size for l in leaves) < max(p.size for p in values)


class TestMicrobatching:
    def test_microbatch_matches_full_batch_loss(self):
        cfg, model, run, opt, state = _setup()
        run_mb = dataclasses.replace(run, microbatch=4)
        step_full = jax.jit(build_train_step(model, run, opt))
        step_mb = jax.jit(build_train_step(model, run_mb, make_optimizer(run_mb)))
        batch = dummy_train_inputs(cfg, 8, 64, seed=0)
        _, m_full = step_full(state, batch)
        cfg2, model2, run2, opt2, state2 = _setup()
        _, m_mb = step_mb(state2, batch)
        # mean loss over microbatches == full-batch loss (same token count)
        assert abs(float(m_full["loss"]) - float(m_mb["loss"])) < 5e-2


class TestRedoxTraining:
    def test_loader_feeds_train_step_multi_epoch(self, tmp_path):
        cfg, model, run, opt, state = _setup()
        cfg = dataclasses.replace(cfg, vocab_size=97)
        model = build_model(cfg)
        state = init_train_state(model, opt, 0)
        step = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
        ds = SyntheticTokenDataset(96, cfg.vocab_size, mean_len=40, seed=0)
        store = ds.build_store(tmp_path / "c", 4, num_slots=16, seed=1)
        cluster = Cluster(store.plan, 2, store=store, seed=2)
        sampler = EpochSampler(96, 2, seed=3)
        loader = RedoxLoader(cluster, sampler, batch_per_node=4, seq_len=48)
        losses = []
        for epoch in range(2):
            for b in loader.epoch(epoch):
                state, m = step(
                    state,
                    {k: jnp.asarray(b[k]) for k in ("tokens", "targets", "loss_mask")},
                )
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
        assert np.mean(losses[-4:]) < np.mean(losses[:4])

    def test_grad_allreduce_dtype_flag(self):
        cfg, model, run, opt, state = _setup(grad_allreduce_dtype="bfloat16")
        step = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
        state, m = step(state, dummy_train_inputs(cfg, 4, 64, seed=0))
        assert np.isfinite(float(m["loss"]))
