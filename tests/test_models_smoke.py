"""Per-architecture smoke tests (assignment deliverable (f)).

Each assigned arch instantiates a REDUCED same-family config and runs a
real forward + train step on CPU, asserting output shapes and no NaNs.
Full configs are exercised only via the dry-run (ShapeDtypeStruct).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, RunConfig, get_config, list_archs, reduced
from repro.launch.specs import dummy_train_inputs
from repro.models import build_model, split_params
from repro.optim.optimizers import make_optimizer
from repro.train.train_step import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
    init_train_state,
)

ALL = list_archs()


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ARCHS[name])
            model = build_model(cfg)
            values, axes = split_params(model.init(0))
            cache[name] = (cfg, model, values)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name, built):
    cfg, model, values = built(name)
    b, s = 2, 128
    inputs = dummy_train_inputs(cfg, b, s, seed=1)
    logits, aux, _ = model.forward(values, inputs)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{name}: NaNs"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ALL)
def test_train_step_descends(name, built):
    cfg, model, _ = built(name)
    run = RunConfig(optimizer="adamw", learning_rate=1e-3)
    opt = make_optimizer(run)
    state = init_train_state(model, opt, 0)
    step = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
    batch = dummy_train_inputs(cfg, 4, 64, seed=0)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], f"{name}: loss did not descend {losses}"


@pytest.mark.parametrize(
    "name", [n for n in ALL if ARCHS[n].supports_decode()]
)
def test_prefill_decode_consistency(name, built):
    """decode(cache(prefill(x[:T]))) logits == forward(x[:T+1]) at position T.

    MoE archs get a large capacity factor so token-dropping (which
    legitimately differs between batched prefill and one-token decode)
    cannot mask a real cache bug. The VLM arch prefixes patch embeddings in
    both paths.
    """
    import dataclasses

    cfg, model, values = built(name)
    if cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
        model = build_model(cfg)
    rng = np.random.default_rng(3)
    T, b = 16, 2
    toks = rng.integers(0, cfg.vocab_size, (b, T + 1)).astype(np.int32)
    if cfg.frontend == "patch":
        p = cfg.frontend_len
        patches = jnp.asarray(rng.normal(size=(b, p, cfg.frontend_dim)), jnp.float32)
        full_inp = {"tokens": jnp.asarray(toks), "patch_embeds": patches}
        pre_inp = {"tokens": jnp.asarray(toks[:, :T]), "patch_embeds": patches}
        pos_t = p + T
    else:
        full_inp = {"tokens": jnp.asarray(toks)}
        pre_inp = {"tokens": jnp.asarray(toks[:, :T])}
        pos_t = T
    full, _, _ = model.forward(values, full_inp)
    prefill = build_prefill_step(model, max_len=pos_t + 8)
    decode = build_decode_step(model)
    _, cache = prefill(values, pre_inp)
    lg, cache = decode(values, cache, jnp.asarray(toks[:, T : T + 1]), jnp.int32(pos_t))
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, pos_t])))
    assert err < 5e-3, f"{name}: prefill/decode mismatch {err}"


@pytest.mark.parametrize(
    "name", [n for n in ALL if ARCHS[n].supports_decode()]
)
def test_multi_step_decode_finite(name, built):
    cfg, model, values = built(name)
    b = 2
    cache = model.init_cache(batch=b, max_len=64)
    decode = build_decode_step(model)
    tok = jnp.zeros((b, 1), jnp.int32)
    for t in range(4):
        lg, cache = decode(values, cache, tok, jnp.int32(t))
        assert np.isfinite(np.asarray(lg, np.float32)).all()
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)


def test_param_count_formula_matches_dense():
    """ModelConfig.param_count() is exact for attention-family archs."""
    for name in ("tinyllama-1.1b", "deepseek-7b", "deepseek-moe-16b", "hubert-xlarge", "llava-next-34b"):
        cfg = reduced(ARCHS[name])
        model = build_model(cfg)
        values, _ = split_params(model.init(0))
        actual = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(values))
        assert cfg.param_count() == actual, (name, cfg.param_count(), actual)


def test_full_config_layer_structure():
    """Full configs expose the exact assigned hyperparameters."""
    sc = get_config("starcoder2-15b")
    assert (sc.num_layers, sc.d_model, sc.num_heads, sc.num_kv_heads) == (40, 6144, 48, 4)
    assert (sc.d_ff, sc.vocab_size) == (24576, 49152)
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.moe_num_experts, kimi.moe_top_k) == (384, 8)
    assert kimi.param_count() > 0.9e12, "kimi must be ~1T params"
    z = get_config("zamba2-1.2b")
    layout = z.block_layout()
    assert layout.count("mamba2") == 38 and layout.count("shared_attn") == 6
    x = get_config("xlstm-350m")
    lx = x.block_layout()
    assert lx.count("slstm") == 3 and lx.count("mlstm") == 21


def test_reduced_zamba_has_shared_attention(built):
    cfg, model, values = built("zamba2-1.2b")
    assert "shared_attn" in values
    assert any(k == "shared_attn" for k, _ in cfg.segments())
