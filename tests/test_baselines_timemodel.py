"""Baseline loaders (PyTorch-style, CoorDL, No-IO) + the epoch-time model."""

import numpy as np

from repro.core import (
    ChunkingPlan,
    Cluster,
    CoorDLLoader,
    EpochSampler,
    NoIOLoader,
    PipelineTimeModel,
    PyTorchStyleLoader,
    StepIO,
    run_baseline_epoch,
)


def make(n=2000, nodes=2, mem_frac=0.3, seed=0):
    sizes = np.full(n, 1000, dtype=np.int64)
    plan = ChunkingPlan.create(sizes, 8, num_slots=64, seed=seed)
    sampler = EpochSampler(n, nodes, seed=seed + 1)
    mem = int(sizes.sum() * mem_frac / nodes)
    return plan, sampler, mem


class TestBaselines:
    def test_coordl_hit_rate_matches_cache_fraction(self):
        plan, sampler, mem = make(mem_frac=0.4)
        loader = CoorDLLoader(plan, 2, mem)
        stats, _ = run_baseline_epoch(loader, sampler, 0, 32)
        cached_frac = (loader.cached_on >= 0).mean()
        hit_frac = (stats.local_hits + stats.remote_requests) / stats.accesses
        assert abs(hit_frac - cached_frac) < 0.02
        assert stats.remote_requests > 0  # peer-cache sharing active

    def test_pytorch_lru_thrashes_under_random_exactly_once(self):
        """Paper §2.1: with dataset >> memory and a fresh shuffle each epoch,
        LRU hit rate collapses toward the memory fraction."""
        plan, sampler, mem = make(mem_frac=0.25)
        loader = PyTorchStyleLoader(plan, 2, mem)
        run_baseline_epoch(loader, sampler, 0, 32)  # warm epoch
        loader.stats = type(loader.stats)()
        stats, _ = run_baseline_epoch(loader, sampler, 1, 32)
        hit = stats.local_hits / stats.accesses
        assert hit < 0.3, hit

    def test_no_io_has_zero_demand(self):
        plan, sampler, _ = make()
        stats, io = run_baseline_epoch(NoIOLoader(plan, 2), sampler, 0, 32)
        assert stats.disk_bytes == 0
        assert all(x.disk_bytes == 0 and x.file_reads == 0 for s in io for x in s)

    def test_redox_reads_fewer_ops_than_pytorch(self):
        """The paper's core effect: chunked reads collapse per-file ops."""
        plan, sampler, mem = make(mem_frac=0.3)
        pt = PyTorchStyleLoader(plan, 2, mem)
        pt_stats, _ = run_baseline_epoch(pt, sampler, 0, 32)
        cluster = Cluster(plan, 2, seed=0)
        res = cluster.run_epoch(sampler, 0, 32, collect_returned=False)
        assert res.stats.chunk_loads < pt_stats.memory_misses / 2


class TestTimeModel:
    TM = PipelineTimeModel(
        disk_bw=100e6, file_overhead=5e-3, chunk_overhead=5e-3,
        net_bw=1e9, net_latency=1e-3,
    )

    def test_io_time_components(self):
        io = StepIO(chunk_loads=2, disk_bytes=100e6, file_reads=10,
                    net_messages=4, net_bytes=1e9)
        t = self.TM.io_time(io)
        assert abs(t - (2 * 5e-3 + 1.0 + 10 * 5e-3 + 4e-3 + 1.0)) < 1e-9

    def test_epoch_pipelined_bound(self):
        steps = [[StepIO(disk_bytes=50e6)] * 4]  # 0.5s io per step
        # compute-bound: 4x1.0 + pipeline fill (0.5)
        assert abs(self.TM.epoch_time(steps, compute_per_step=1.0) - 4.5) < 1e-9
        # io-bound: 4x0.5 + fill
        assert abs(self.TM.epoch_time(steps, compute_per_step=0.1) - 2.5) < 1e-9

    def test_epoch_strict_no_queue(self):
        steps = [[StepIO(disk_bytes=50e6)] * 4]
        assert abs(self.TM.epoch_time_strict(steps, compute_per_step=1.0) - 4.0) < 1e-9
        assert abs(self.TM.epoch_time_strict(steps, compute_per_step=0.1) - 2.0) < 1e-9

    def test_epoch_max_over_nodes(self):
        fast = [StepIO()] * 3
        slow = [StepIO(disk_bytes=100e6)] * 3  # 1s io/step
        t = self.TM.epoch_time([fast, slow], compute_per_step=0.2)
        assert abs(t - 4.0) < 1e-9  # max(0.6, 3.0) + 1.0 fill
