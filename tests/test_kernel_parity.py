"""Kernel parity harness: every kernel vs its pure-jnp oracle.

The shape x dtype grid and the per-dtype tolerances live in
``repro.kernels.parity`` — the same registry ``benchmarks/device_path.py``
prints as a table — so the CI sweep and the benchmark can never drift
apart. Semantics edge cases that a grid sweep cannot express (sliding
windows, block-shape independence, ring-buffer masks, duplicate
redirection indices) are kept as explicit tests below.

Runs in interpret mode on CPU (``interpret=None`` auto-detects); on a
real TPU the identical suite exercises the compiled kernels.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import parity
from repro.kernels.chunk_gather.ops import chunk_gather, chunk_gather_train
from repro.kernels.chunk_gather.ref import chunk_gather_train_ref
from repro.kernels.common import resolve_interpret
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention, flash_attention_gqa
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan

pytestmark = pytest.mark.kernels

RNG = np.random.default_rng(7)


# ----------------------------------------------------------- registry sweep
@pytest.mark.parametrize(
    "case", parity.iter_cases(), ids=lambda c: c.name
)
def test_parity_grid(case):
    r = parity.check_case(case)
    assert r["ok"], (
        f"{r['case']}: max err {r['max_err']:.3e} exceeds tol {r['tol']:.0e}"
    )


def test_grid_covers_every_kernel():
    """The sweep must touch all four kernel packages (and stay in sync
    with the registry if one is added)."""
    swept = {c.kernel for c in parity.iter_cases()}
    assert swept == set(parity.KERNELS) and len(swept) >= 4


def test_interpret_auto_detection():
    """interpret=None resolves per backend: interpreted off-TPU, compiled
    on TPU; explicit values pass through."""
    import jax

    auto = resolve_interpret(None)
    assert auto == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


# --------------------------------------------------- flash_attention extras
class TestFlashAttentionEdges:
    @pytest.mark.parametrize("window", [32, 96, 1024])
    def test_sliding_window(self, window):
        bh, s, d = 2, 256, 64
        q, k, v = (jnp.asarray(RNG.normal(size=(bh, s, d)), jnp.float32) for _ in range(3))
        out = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_block_shape_independence(self):
        bh, s, d = 2, 256, 64
        q, k, v = (jnp.asarray(RNG.normal(size=(bh, s, d)), jnp.float32) for _ in range(3))
        outs = [
            flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5, rtol=1e-5)

    def test_gqa_wrapper(self):
        b, s, h, kvh, d = 2, 128, 8, 2, 32
        q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        out = flash_attention_gqa(q, k, v, block_q=64, block_k=64)
        assert out.shape == (b, s, h, d)
        assert np.isfinite(np.asarray(out, np.float32)).all()


# -------------------------------------------------- decode_attention extras
class TestDecodeAttentionEdges:
    def test_ring_buffer_mask(self):
        """Rotating-window cache = arbitrary validity pattern; exactness."""
        b, h, kvh, s, d = 1, 4, 2, 256, 64
        q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.float32)
        ck = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        cv = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        # only slots [64:128) valid, as after ring wrap-around
        mask = jnp.zeros((b, s), bool).at[:, 64:128].set(True)
        out = decode_attention(q, ck, cv, mask, block_k=64)
        qg = q.reshape(b * kvh, h // kvh, d)

        def fold(t):
            return t.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)

        m = jnp.repeat(mask[:, None, :], kvh, 1).reshape(b * kvh, s)
        ref = decode_attention_ref(qg, fold(ck), fold(cv), m).reshape(b, h, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------ chunk_gather extras
class TestChunkGatherEdges:
    def test_duplicate_indices(self):
        """Redirection may serve the same slot to multiple rows in a step."""
        ct = jnp.asarray(RNG.integers(1, 100, (8, 32)), jnp.int32)
        lens = jnp.full((8,), 32, jnp.int32)
        idx = jnp.asarray([3, 3, 3, 0], jnp.int32)
        t, _ = chunk_gather(ct, lens, idx)
        np.testing.assert_array_equal(np.asarray(t[0]), np.asarray(t[1]))
        np.testing.assert_array_equal(np.asarray(t[0]), np.asarray(ct[3]))

    def test_train_matches_host_grid_semantics(self):
        """chunk_gather_train == the loader's _to_grid slicing: tokens are
        row[:-1], targets row[1:], mask aligned to targets."""
        slots, full, b = 6, 33, 9  # seq_len 32
        ct = jnp.asarray(RNG.integers(1, 500, (slots, 40)), jnp.int32)
        lens = jnp.asarray([1, 5, 33, 17, 40, 2], jnp.int32).clip(max=full)
        idx = jnp.asarray(RNG.integers(0, slots, (b,)), jnp.int32)
        tok, tgt, mask = chunk_gather_train(ct, lens, idx, seq_len=32, pad_id=0)
        rt, rg, rm = chunk_gather_train_ref(ct, lens, idx, seq_len=32, pad_id=0)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(rt))
        np.testing.assert_array_equal(np.asarray(tgt), np.asarray(rg))
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(rm))
        # length-1 record (slot 0): no target at all -> all-zero mask row
        rows = np.flatnonzero(np.asarray(idx) == 0)
        for r in rows:
            assert np.asarray(mask)[r].sum() == 0

    def test_train_duplicate_slots_share_one_row(self):
        ct = jnp.asarray(RNG.integers(1, 100, (8, 40)), jnp.int32)
        lens = jnp.full((8,), 33, jnp.int32)
        idx = jnp.asarray([5, 5, 2, 5], jnp.int32)
        tok, tgt, _ = chunk_gather_train(ct, lens, idx, seq_len=32)
        np.testing.assert_array_equal(np.asarray(tok[0]), np.asarray(tok[1]))
        np.testing.assert_array_equal(np.asarray(tok[0]), np.asarray(tok[3]))
        np.testing.assert_array_equal(np.asarray(tok[0]), np.asarray(ct[5, :32]))
        np.testing.assert_array_equal(np.asarray(tgt[0]), np.asarray(ct[5, 1:33]))


# ---------------------------------------------------------- ssd_scan extras
class TestSSDScanEdges:
    def test_chunk_size_independence(self):
        bh, s, p, n = 2, 256, 32, 16
        x = jnp.asarray(RNG.normal(size=(bh, s, p)), jnp.float32)
        dt = jnp.asarray(RNG.random((bh, s)) * 0.3 + 0.01, jnp.float32)
        a = jnp.asarray(-RNG.random((bh, 1)) - 0.1, jnp.float32)
        b = jnp.asarray(RNG.normal(size=(bh, s, n)), jnp.float32)
        c = jnp.asarray(RNG.normal(size=(bh, s, n)), jnp.float32)
        outs = [np.asarray(ssd_scan(x, dt, a, b, c, chunk=cs)) for cs in (32, 64, 128, 256)]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, atol=1e-4, rtol=1e-4)
